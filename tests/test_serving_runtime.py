"""Continuous-batching serving runtime robustness tests (DESIGN.md §8).

The Server is model-agnostic, so these tests drive it with pure-python step
functions: what matters here is the runtime's robustness semantics —
admission control, deadlines, fault containment, degraded mode, and the
request-accounting identity (served + shed + rejected + failed + invalid ==
submitted).
"""
import numpy as np
import pytest

from repro.serving.server import (
    BatchExecutionError,
    Batcher,
    DeadlineExceeded,
    QueueFull,
    RequestHandle,
    Server,
    ServingError,
)


def _echo_step(payloads):
    return [p for p in payloads]


class FakeClock:
    """Deterministic injectable clock (the servebench simulation clock)."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _accounting_ok(srv) -> bool:
    s = srv.stats()
    return s["submitted"] == (
        s["served"] + s["shed"] + s["rejected"] + s["failed"] + s["invalid"]
        + s["pending"]
    )


# ------------------------------------------------------------ fault containment


def test_step_error_fails_only_its_batch_handles():
    """Regression: an exception from step_fn used to propagate out of pump()
    and leave every RequestHandle in the batch permanently pending."""
    boom = {"on": True}

    def step(payloads):
        if boom["on"]:
            raise RuntimeError("kernel crashed")
        return [p for p in payloads]

    srv = Server(step, max_batch=4, max_wait_s=0.0)
    bad = [srv.submit_request(i) for i in range(4)]
    out = srv.pump()  # must not raise
    assert out is None
    assert srv.batch_failures == 1
    assert all(h.done() for h in bad), "failed batch left handles pending"
    for h in bad:
        with pytest.raises(Exception, match="kernel crashed"):
            h.result()

    # the pump is not poisoned: the next batch serves normally
    boom["on"] = False
    good = [srv.submit_request(i) for i in range(4)]
    srv.pump()
    assert all(h.done() for h in good)
    assert [h.result() for h in good] == [0, 1, 2, 3]


def test_typed_exception_hierarchy():
    assert issubclass(QueueFull, ServingError)
    assert issubclass(DeadlineExceeded, ServingError)
    assert issubclass(BatchExecutionError, ServingError)
    assert issubclass(ServingError, RuntimeError)


def test_server_validates_knobs():
    for bad in (
        dict(max_batch=0),
        dict(max_wait_s=-1.0),
        dict(admission="drop-newest"),
        dict(max_queue=0),
        dict(deadline_s=0.0),
        dict(probe_every=0),
    ):
        with pytest.raises(ValueError):
            Server(_echo_step, **bad)


# ------------------------------------------------------------ admission control


def test_reject_policy_fails_new_requests():
    srv = Server(_echo_step, max_batch=4, max_wait_s=60.0,
                 max_queue=2, admission="reject")
    ok = [srv.submit_request(i) for i in range(2)]
    spill = srv.submit_request(99)
    # a rejected request comes back as an already-failed handle
    assert spill.done()
    with pytest.raises(QueueFull):
        spill.result()
    # fire-and-forget has no handle to fail: it raises
    with pytest.raises(QueueFull):
        srv.submit(100)
    assert srv.rejected == 2
    assert srv.drain() == []
    assert all(h.result() in (0, 1) for h in ok)
    assert _accounting_ok(srv)


def test_shed_oldest_policy_keeps_fresh_traffic():
    srv = Server(_echo_step, max_batch=4, max_wait_s=60.0,
                 max_queue=2, admission="shed-oldest")
    handles = [srv.submit_request(i) for i in range(6)]
    # 0..3 shed oldest-first; 4, 5 still queued
    for h in handles[:4]:
        assert h.done()
        with pytest.raises(QueueFull, match="shed"):
            h.result()
    srv.drain()
    assert [handles[4].result(), handles[5].result()] == [4, 5]
    assert srv.shed == 4 and srv.served == 2 and srv.rejected == 0
    assert _accounting_ok(srv)


def test_block_policy_pumps_in_place():
    """Cooperative backpressure: a full queue makes the submitter drain a
    batch instead of growing memory or deadlocking."""
    calls = []

    def step(payloads):
        calls.append(len(payloads))
        return list(payloads)

    srv = Server(step, max_batch=4, max_wait_s=60.0,
                 max_queue=4, admission="block")
    handles = [srv.submit_request(i) for i in range(12)]
    assert len(srv.batcher.queue) <= 4
    srv.drain()
    assert [h.result() for h in handles] == list(range(12))
    assert srv.rejected == 0 and srv.shed == 0 and srv.served == 12
    assert max(calls) <= 4
    assert _accounting_ok(srv)


# ------------------------------------------------------------------- deadlines


def test_deadline_sheds_before_execution():
    clock = FakeClock()
    executed = []

    def step(payloads):
        executed.extend(payloads)
        return list(payloads)

    srv = Server(step, max_batch=8, max_wait_s=0.0, deadline_s=0.5,
                 clock=clock.now)
    stale = srv.submit_request("stale")
    fresh_h = srv.submit_request("fresh", deadline_s=10.0)  # per-request override
    clock.advance(1.0)  # stale's deadline (0.5s) passes; fresh's (10s) holds
    srv.pump()
    assert stale.done()
    with pytest.raises(DeadlineExceeded):
        stale.result()
    assert fresh_h.result() == "fresh"
    assert "stale" not in executed, "expired request reached the executor"
    assert srv.deadline_misses == 1 and srv.shed == 1 and srv.served == 1
    assert _accounting_ok(srv)


def test_handle_wait_timeout():
    srv = Server(_echo_step, max_batch=2, max_wait_s=60.0)
    h = srv.submit_request(7)
    assert h.wait(timeout=0.01) is False  # pending: nothing pumps
    srv.drain()
    assert h.wait(timeout=0.01) is True
    assert h.result() == 7


# ---------------------------------------------------------- adaptive batching


def test_adaptive_release_beats_lockstep_on_sparse_traffic():
    """At a trickle arrival rate the batch cannot fill before max_wait, so
    the adaptive batcher releases immediately instead of parking every
    query for the full wait budget."""
    clock = FakeClock()
    lockstep = Batcher(max_batch=8, max_wait_s=5.0, clock=clock.now)
    adaptive = Batcher(max_batch=8, max_wait_s=5.0, adaptive=True,
                       clock=clock.now)
    for b in (lockstep, adaptive):
        b.submit("a", now=0.0)
        b.submit("b", now=1.0)  # observed gap: 1s -> fill needs 6 more s
    clock.t = 1.0
    assert lockstep.maybe_release() is None  # parks until t=5
    batch = adaptive.maybe_release()
    assert batch is not None and len(batch) == 2
    # under a fast stream (gap ~ 0) the adaptive batcher still waits to fill
    fast = Batcher(max_batch=8, max_wait_s=5.0, adaptive=True, clock=clock.now)
    for i in range(4):
        fast.submit(i, now=1.0 + i * 1e-4)
    clock.t = 1.0 + 4e-4
    assert fast.maybe_release() is None  # batch will fill well within budget


def test_adaptive_release_respects_deadlines():
    """An imminent queued deadline shrinks the wait budget below max_wait."""
    clock = FakeClock()
    b = Batcher(max_batch=8, max_wait_s=5.0, adaptive=True, clock=clock.now)
    b.submit("a", now=0.0, deadline=1.5)
    b.submit("b", now=1.0, deadline=2.5)
    clock.t = 1.0
    # fill needs ~6s more but "a" dies at 1.5 -> release now, not at t=5
    batch = b.maybe_release()
    assert batch is not None and [q.payload for q in batch] == ["a", "b"]


# ------------------------------------------------------- degraded mode / faults


def test_degraded_mode_serves_via_fallback_and_probes_back():
    boom = {"on": True}
    calls = {"primary": 0, "fallback": 0}

    def primary(payloads):
        calls["primary"] += 1
        if boom["on"]:
            raise RuntimeError("fused kernel crash")
        return list(payloads)

    def fallback(payloads):
        calls["fallback"] += 1
        return list(payloads)

    srv = Server(primary, max_batch=2, max_wait_s=0.0,
                 fallback_step_fn=fallback, degrade_after=3, probe_every=2)
    # two failing batches: handles fail, server still healthy
    failed = []
    for b in range(2):
        failed += [srv.submit_request(i) for i in (0, 1)]
        assert srv.pump() is None
    assert not srv.degraded and srv.batch_failures == 2
    for h in failed:
        with pytest.raises(BatchExecutionError, match="kernel crash"):
            h.result()
    # third consecutive failure degrades; THIS batch is served via fallback
    ok = [srv.submit_request(i) for i in (2, 3)]
    srv.pump()
    assert srv.degraded and srv.degraded_batches == 1
    assert [h.result() for h in ok] == [2, 3]
    # degraded serving continues on the fallback; probes keep failing
    for b in range(4):
        h = srv.submit_request(b)
        srv.pump()
        assert h.result() == b
    assert srv.degraded and srv.probes >= 1 and srv.probe_failures >= 1
    # primary heals: the next probe returns the server to the fused path
    boom["on"] = False
    healed = None
    for b in range(srv.probe_every):
        healed = srv.submit_request(b)
        srv.pump()
    assert not srv.degraded
    assert healed.done() and srv.batch_failures == 2  # no new failures
    fallback_calls = calls["fallback"]
    h = srv.submit_request(42)
    srv.pump()
    assert h.result() == 42
    assert calls["fallback"] == fallback_calls, "healthy server used fallback"
    # every submitted request is accounted for
    assert _accounting_ok(srv)
    s = srv.stats()
    assert s["failed"] == 4 and s["batch_failures"] == 2
    assert s["served"] == s["submitted"] - 4


def test_no_fallback_means_no_degraded_mode():
    def primary(payloads):
        raise RuntimeError("always down")

    srv = Server(primary, max_batch=1, max_wait_s=0.0, degrade_after=2)
    handles = [srv.submit_request(i) for i in range(5)]
    srv.drain()
    assert not srv.degraded and srv.degraded_batches == 0
    assert srv.batch_failures == 5
    for h in handles:
        with pytest.raises(BatchExecutionError):
            h.result()
    assert _accounting_ok(srv)


def test_fallback_failure_fails_the_batch():
    def primary(payloads):
        raise RuntimeError("primary down")

    def fallback(payloads):
        raise RuntimeError("fallback also down")

    srv = Server(primary, max_batch=1, max_wait_s=0.0,
                 fallback_step_fn=fallback, degrade_after=1)
    h = srv.submit_request(0)
    assert srv.pump() is None
    with pytest.raises(BatchExecutionError, match="fallback also down"):
        h.result()
    assert srv.degraded  # degraded entry happened even though fallback died
    assert _accounting_ok(srv)


# ------------------------------------------------------------------ drain/flush


def test_drain_force_flushes_partial_batches():
    """Regression: with queue < max_batch and max_wait not elapsed, drain()
    used to spin max_iters no-op pumps and silently leave the queue."""
    calls = []

    def step(payloads):
        calls.append(len(payloads))
        return list(payloads)

    srv = Server(step, max_batch=8, max_wait_s=60.0)
    handles = [srv.submit_request(i) for i in range(3)]
    unserved = srv.drain()
    assert unserved == []
    assert calls == [3]  # ONE forced partial batch, not 10k no-op spins
    assert [h.result() for h in handles] == [0, 1, 2]


def test_drain_reports_unserved_queries():
    srv = Server(_echo_step, max_batch=1, max_wait_s=60.0)
    for i in range(3):
        srv.submit(i)
    left = srv.drain(max_iters=1)  # budget for only one forced pump
    assert [q.payload for q in left] == [1, 2]
    assert len(srv.batcher.queue) == 2  # reported, not dropped
    assert srv.drain() == []  # a real drain still serves them
    assert srv.served == 3


def test_flush_releases_one_partial_batch():
    srv = Server(_echo_step, max_batch=8, max_wait_s=60.0)
    srv.submit(1)
    assert srv.pump() is None  # lockstep rule holds the partial batch
    assert srv.flush() == [1]


# ------------------------------------------------------------- engine wiring


@pytest.fixture(scope="module")
def small_engine():
    import jax

    from repro.data.workloads import small_workload
    from repro.engine import EngineConfig, InferenceEngine

    wl = small_workload(batch=8)
    config = EngineConfig(mesh_shape=(1, 1), max_batch=8, max_wait_s=0.0)
    engine = InferenceEngine.build(None, wl, config)
    return engine, wl


def test_engine_degraded_fallback_is_parity_identical(small_engine):
    """A crashing fused step degrades to the XLA reference path on the SAME
    packed tables: results keep flowing and are bit-identical to lookup()."""
    import jax

    from repro.data.distributions import Zipf, sample_workload

    engine, wl = small_engine
    idx = np.asarray(
        sample_workload(np.random.default_rng(0), wl, Zipf(1.2), 8)
    )
    expected = np.asarray(engine.lookup(jax.numpy.asarray(idx)))

    srv = engine.serve(degrade_after=2)
    assert srv.fallback_step_fn is not None
    primary = srv.step_fn

    crashes = {"n": 0}

    def crashing(payloads):
        crashes["n"] += 1
        raise RuntimeError("injected fused crash")

    crashing.bag = engine.bag
    srv.step_fn = crashing
    dead = [srv.submit_request(idx[:, q]) for q in range(8)]
    srv.pump()  # failure 1: handles fail
    for h in dead:
        with pytest.raises(BatchExecutionError):
            h.result()
    live = [srv.submit_request(idx[:, q]) for q in range(8)]
    srv.pump()  # failure 2: degrades, batch served via the reference path
    assert srv.degraded and srv.degraded_batches == 1
    for q, h in enumerate(live):
        np.testing.assert_allclose(
            np.asarray(h.result()), expected[:, q], rtol=1e-5, atol=1e-6
        )
    # heal the primary: a probe swaps the fused path back in
    srv.step_fn = primary
    for _ in range(srv.probe_every):
        again = [srv.submit_request(idx[:, q]) for q in range(8)]
        srv.pump()
    assert not srv.degraded
    for q, h in enumerate(again):
        np.testing.assert_array_equal(np.asarray(h.result()), expected[:, q])
    assert _accounting_ok(srv)


def test_engine_config_serving_validation():
    from repro.engine import EngineConfig

    for field, bad in (
        ("max_batch", 0), ("max_batch", -4), ("max_wait_s", -0.1),
        ("admission", "lifo"), ("max_queue", 0), ("deadline_s", -1.0),
        ("degrade_after", -1), ("probe_every", 0),
    ):
        cfg = EngineConfig(**{field: bad})
        with pytest.raises(ValueError):
            cfg.validate()
    # serving fields round-trip through the JSON artifact
    cfg = EngineConfig(max_queue=512, admission="shed-oldest",
                       deadline_s=0.05, adaptive_batching=True)
    cfg.validate()
    from repro.engine import EngineConfig as EC

    assert EC.from_json(cfg.to_json()) == cfg


def test_engine_serve_respects_admission_config(small_engine):
    engine, wl = small_engine
    import dataclasses

    from repro.data.distributions import Uniform, sample_workload

    idx = np.asarray(
        sample_workload(np.random.default_rng(1), wl, Uniform(), 8)
    )
    cfg = dataclasses.replace(
        engine.config, max_queue=4, admission="reject", deadline_s=5.0
    )
    engine2 = dataclasses.replace  # noqa: F841  (clarity: new config only)
    from repro.engine import InferenceEngine

    eng = InferenceEngine(
        config=cfg, workload=engine.workload, bag=engine.bag,
        packed=engine.packed, mesh=engine.mesh, freqs=engine.freqs,
        table_data=engine.table_data, cost_model=engine.cost_model,
    )
    srv = eng.serve(max_batch=8, max_wait_s=60.0)
    assert srv.max_queue == 4 and srv.admission == "reject"
    assert srv.deadline_s == 5.0
    handles = [srv.submit_request(idx[:, q % 8]) for q in range(6)]
    assert srv.rejected == 2
    assert sum(1 for h in handles if h.done()) == 2  # the two rejections
    srv.drain()
    assert _accounting_ok(srv)

"""Continuous-batching serving runtime robustness tests (DESIGN.md §8).

The Server is model-agnostic, so these tests drive it with pure-python step
functions: what matters here is the runtime's robustness semantics —
admission control, deadlines, fault containment, degraded mode, and the
request-accounting identity (served + shed + rejected + failed == submitted).
"""
import numpy as np
import pytest

from repro.serving.server import Server


def _echo_step(payloads):
    return [p for p in payloads]


# ------------------------------------------------------------ fault containment


def test_step_error_fails_only_its_batch_handles():
    """Regression: an exception from step_fn used to propagate out of pump()
    and leave every RequestHandle in the batch permanently pending."""
    boom = {"on": True}

    def step(payloads):
        if boom["on"]:
            raise RuntimeError("kernel crashed")
        return [p for p in payloads]

    srv = Server(step, max_batch=4, max_wait_s=0.0)
    bad = [srv.submit_request(i) for i in range(4)]
    out = srv.pump()  # must not raise
    assert out is None
    assert srv.batch_failures == 1
    assert all(h.done() for h in bad), "failed batch left handles pending"
    for h in bad:
        with pytest.raises(Exception, match="kernel crashed"):
            h.result()

    # the pump is not poisoned: the next batch serves normally
    boom["on"] = False
    good = [srv.submit_request(i) for i in range(4)]
    srv.pump()
    assert all(h.done() for h in good)
    assert [h.result() for h in good] == [0, 1, 2, 3]

"""Ragged packed layout: parity vs the oracle and the legacy dense layout.

Single-process execution (interpret mode on CPU): the per-core partials are
computed by calling the executor's local sweep directly per core and summing
— exactly the psum the SPMD path performs — plus the batch-split symmetric
fallback, so every layout/kernel combination is checked against the pure-jnp
oracle without needing a multi-device mesh.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PartitionedEmbeddingBag, analytic_model, make_workload
from repro.core.cost_model import TPU_V5E
from repro.core.embedding import stack_indices
from repro.core.partition import (
    _local_asym_lookup,
    _local_sym_lookup,
    pack_plan,
)
from repro.core.strategies import ChunkAssignment, Plan, Strategy

E = 16


def _small_model(l1_bytes=4096):
    return analytic_model(dataclasses.replace(TPU_V5E, l1_bytes=l1_bytes))


def _emulated_lookup(packed, sidx, n_tables, use_kernels):
    """Per-core local sweeps + psum + batch-split symmetric fallback."""
    k = packed.n_cores
    b = sidx.shape[1]
    out = jnp.zeros((n_tables, b, E), jnp.float32)
    for core in range(k):
        out = out + _local_asym_lookup(
            packed.strip_core(core), sidx, n_tables=n_tables,
            use_kernels=use_kernels,
        )
    bl = b // k
    syms = [
        _local_sym_lookup(
            packed, sidx[:, core * bl : (core + 1) * bl],
            n_tables=n_tables, use_kernels=use_kernels,
        )
        for core in range(k)
    ]
    return out + jnp.concatenate(syms, axis=1)


def _random_indices(wl, seed=10):
    return [
        jax.random.randint(
            jax.random.PRNGKey(seed + i), (wl.batch, t.seq), 0, t.rows
        )
        for i, t in enumerate(wl.tables)
    ]


def _check_all_paths(bag, params, idx, atol=1e-5):
    want = np.asarray(bag.reference(params, idx))
    sidx = stack_indices(idx, bag.s_max)
    outs = {}
    for layout in ("ragged", "dense"):
        packed = bag.pack(params, layout=layout)
        for uk in (False, True, "fused"):
            got = np.asarray(
                _emulated_lookup(packed, sidx, bag.n_tables, uk)
            )
            np.testing.assert_allclose(
                got, want, rtol=1e-5, atol=atol,
                err_msg=f"layout={layout} use_kernels={uk}",
            )
            outs[(layout, uk)] = got
    # ragged fused vs old dense path, elementwise
    np.testing.assert_allclose(
        outs[("ragged", "fused")], outs[("dense", False)], rtol=1e-5, atol=atol
    )


# --------------------------------------------------------------------------
# parity across planner shapes
# --------------------------------------------------------------------------


@pytest.mark.parametrize("planner", ["baseline", "symmetric", "asymmetric"])
def test_parity_all_planners(planner):
    """Mixed table sizes, chunking, empty slots, and the symmetric group."""
    wl = make_workload(
        "t", [100, 57, 1000, 8, 3000, 16, 450, 333], dim=E,
        seqs=[1, 2, 1, 4, 1, 1, 3, 1], batch=64,
    )
    bag = PartitionedEmbeddingBag(
        wl, n_cores=4, planner=planner, cost_model=_small_model()
    )
    params = bag.init(jax.random.PRNGKey(0))
    _check_all_paths(bag, params, _random_indices(wl))


def test_parity_skewed_one_big_many_small():
    """The layout's motivating shape: one huge chunk + many tiny tables."""
    rng = np.random.default_rng(3)
    rows = [20_000] + [int(x) for x in rng.integers(8, 200, 15)]
    wl = make_workload("skew", rows, dim=E, batch=32)
    bag = PartitionedEmbeddingBag(
        wl, n_cores=4, planner="asymmetric", cost_model=_small_model(1 << 20),
        planner_kwargs=dict(lif_threshold=1e9, rock_theta=None),
    )
    # all tables asymmetric: the skew lives in the slots, not the fallback
    assert not bag.plan.symmetric_tables
    params = bag.init(jax.random.PRNGKey(1))
    _check_all_paths(bag, params, _random_indices(wl))


def test_parity_with_replicas():
    """batch_frac replicas: each replica core serves its contiguous slice."""
    wl = make_workload("rep", [512, 64, 96], dim=E, batch=32)
    plan = Plan(
        workload_name="rep",
        n_cores=4,
        assignments=(
            ChunkAssignment(0, 0, 0, 512, Strategy.GM, batch_frac=(0, 2)),
            ChunkAssignment(0, 1, 0, 512, Strategy.L1, batch_frac=(1, 2)),
            ChunkAssignment(1, 2, 0, 64, Strategy.L1_UB),
            ChunkAssignment(2, 3, 0, 96, Strategy.GM_UB),
        ),
        symmetric_tables=(),
        symmetric_strategies=(),
    )
    plan.validate(wl.tables)
    params = [
        jax.random.normal(jax.random.PRNGKey(i), (t.rows, E), jnp.float32)
        for i, t in enumerate(wl.tables)
    ]
    want = None
    sidx = stack_indices(_random_indices(wl), max(t.seq for t in wl.tables))
    for layout in ("ragged", "dense"):
        packed = pack_plan(plan, wl.tables, params, layout=layout)
        for uk in (False, "fused"):
            got = np.asarray(_emulated_lookup(packed, sidx, 3, uk))
            if want is None:
                # oracle: full-batch lookup per table
                outs = []
                for i, t in enumerate(params):
                    g = jnp.take(t, jnp.where(sidx[i] >= 0, sidx[i], 0), axis=0)
                    g = jnp.where((sidx[i] >= 0)[..., None], g, 0.0)
                    outs.append(g.sum(axis=1))
                want = np.asarray(jnp.stack(outs))
            np.testing.assert_allclose(
                got, want, rtol=1e-5, atol=1e-5,
                err_msg=f"layout={layout} use_kernels={uk}",
            )


def test_parity_empty_core():
    """More cores than chunks: some cores carry zero slots."""
    wl = make_workload("empty", [40, 24], dim=E, batch=16)
    bag = PartitionedEmbeddingBag(
        wl, n_cores=8, planner="asymmetric", cost_model=_small_model(1 << 16),
        planner_kwargs=dict(rock_theta=None),
    )
    params = bag.init(jax.random.PRNGKey(2))
    _check_all_paths(bag, params, _random_indices(wl))


# --------------------------------------------------------------------------
# layout geometry + packing efficiency
# --------------------------------------------------------------------------


def test_ragged_buffer_invariants():
    wl = make_workload("inv", [1000, 64, 256, 8], dim=E, batch=16)
    bag = PartitionedEmbeddingBag(
        wl, n_cores=2, planner="asymmetric", cost_model=_small_model(1 << 20),
        planner_kwargs=dict(lif_threshold=1e9, rock_theta=None),
    )
    params = bag.init(jax.random.PRNGKey(0))
    packed = bag.pack(params)
    assert packed.layout == "ragged"
    buf = np.asarray(packed.chunk_data)
    starts = np.asarray(packed.slot_row_start)
    rows = np.asarray(packed.slot_rows)
    tables = np.asarray(packed.slot_table)
    br = packed.block_r
    assert (buf.shape[1] - 1) % br == 0
    # shared trailing zero row
    np.testing.assert_array_equal(buf[:, -1], 0.0)
    for core in range(packed.n_cores):
        for s in range(tables.shape[1]):
            if tables[core, s] < 0:
                continue
            assert starts[core, s] % br == 0
            # chunk data matches the source table slice
            ti = int(tables[core, s])
            off = int(np.asarray(packed.slot_offset)[core, s])
            r = int(rows[core, s])
            np.testing.assert_array_equal(
                buf[core, starts[core, s] : starts[core, s] + r],
                np.asarray(params[ti][off : off + r]),
            )
            # the slot's redirect row (right after the data) is zero
            np.testing.assert_array_equal(
                buf[core, starts[core, s] + r], 0.0
            )
            # the slot's scheduled row-blocks tile exactly its allocation
            alloc = -(-(r + 1) // br) * br
            mask = np.asarray(packed.step_slot)[core] == s
            blocks = np.asarray(packed.step_block)[core][mask]
            np.testing.assert_array_equal(
                np.sort(blocks) * br,
                starts[core, s] + np.arange(alloc // br) * br,
            )


def test_skewed_pack_shrinks_4x():
    """Acceptance: 1-big+31-small packs >= 4x smaller than the dense layout."""
    rng = np.random.default_rng(0)
    rows = [50_000] + [int(x) for x in rng.integers(16, 256, 31)]
    wl = make_workload("zipf", rows, dim=E, batch=32)
    bag = PartitionedEmbeddingBag(
        wl, n_cores=4, planner="asymmetric", cost_model=analytic_model(),
        planner_kwargs=dict(lif_threshold=1e9, rock_theta=None),
    )
    ragged = bag.pack(None, layout="ragged")
    meta = bag.layout_summary()
    assert meta["kind"] == "ragged"
    dense = bag.pack(None, layout="dense")
    assert dense.chunk_bytes == meta["dense_bytes"]
    assert dense.chunk_bytes >= 4 * ragged.chunk_bytes
    assert bag.layout_summary()["kind"] == "dense"  # last pack wins
    # and the fused kernel still matches the oracle on this shape
    params = bag.init(jax.random.PRNGKey(0))
    packed = bag.pack(params, layout="ragged")
    sidx = stack_indices(_random_indices(wl), bag.s_max)
    got = np.asarray(_emulated_lookup(packed, sidx, bag.n_tables, "fused"))
    want = np.asarray(bag.reference(params, _random_indices(wl)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_layout_meta_recorded():
    wl = make_workload("meta", [100, 200], dim=E, batch=16)
    bag = PartitionedEmbeddingBag(
        wl, n_cores=2, planner="asymmetric", cost_model=_small_model()
    )
    bag.pack(None)
    meta = bag.layout_summary()
    assert meta["kind"] == "ragged"
    assert meta["chunk_bytes"] > 0 and meta["dense_bytes"] > 0
    assert 0.0 <= meta["padding_frac"] < 1.0
    assert meta["block_r"] % 8 == 0

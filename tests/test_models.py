"""Per-architecture smoke tests (reduced configs, CPU): one train step with
shape + finiteness assertions, and prefill->decode cache consistency against
the full-sequence forward."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, ShapeCfg
from repro.models import registry
from repro.models import transformer as T
from repro.training.optimizer import adamw

TRAIN_SHAPE = ShapeCfg("smoke", "train", 64, 2)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_train_step_smoke(arch):
    b = registry.build(arch, smoke=True)
    params = b.init(jax.random.PRNGKey(0))
    batch = b.make_batch(TRAIN_SHAPE, jax.random.PRNGKey(1))
    opt = adamw(1e-3)
    step = jax.jit(b.train_step(None, opt, TRAIN_SHAPE))
    p2, o2, m = step(params, opt.init(params), batch)
    assert jnp.isfinite(m["loss"])
    # params updated, structure/shapes preserved, all finite
    jax.tree.map(lambda a, b_: (_ for _ in ()).throw(AssertionError)
                 if a.shape != b_.shape else None, params, p2)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2))
    # a second step with the updated params still works
    p3, o3, m2 = step(p2, o2, batch)
    assert jnp.isfinite(m2["loss"])


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_full_config_instantiates(arch):
    """The FULL config is exercised abstractly (no allocation)."""
    import math

    b = registry.build(arch)
    structs = b.param_struct()
    n = sum(math.prod(x.shape) for x in jax.tree.leaves(structs))
    # full param counts are in the expected ballpark of the published sizes
    expect = {
        "olmo-1b": 1.3e9, "qwen3-0.6b": 0.9e9, "qwen3-1.7b": 2.4e9,
        "chatglm3-6b": 6.8e9, "mamba2-780m": 0.9e9, "qwen2-vl-2b": 2.1e9,
        "whisper-small": 0.3e9, "granite-moe-3b-a800m": 3.5e9,
        "mixtral-8x22b": 141e9, "zamba2-1.2b": 1.4e9,
    }[arch]
    assert 0.5 * expect < n < 2.0 * expect, (arch, n)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode through the serve cache == full forward."""
    S0, EXTRA, B = 16, 4, 2
    b = registry.build(arch, smoke=True)
    cfg = b.cfg
    if cfg.moe is not None:  # disable capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
        )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    S = S0 + EXTRA
    cache_shape = ShapeCfg("t", "decode", S, B)
    full = registry.Bundle(cfg).make_batch(
        ShapeCfg("t", "prefill", S, B), jax.random.PRNGKey(1), act_dtype=jnp.float32
    )
    pre = {}
    for k, v in full.items():
        if k == "positions":
            pre[k] = v[:, :, :S0]
        elif k == "frames":
            pre[k] = v
        else:
            pre[k] = v[:, :S0] if v.ndim >= 2 else v
    logits_p, cache = jax.jit(T.make_prefill_step(cfg, None, cache_shape))(params, pre)
    serve = jax.jit(T.make_serve_step(cfg, None))
    dec_logits = [logits_p]
    for t in range(S0, S):
        db = {}
        if cfg.input_kind == "embeds":
            db["embeds"] = full["embeds"][:, t : t + 1]
            db["positions"] = full["positions"][:, :, t : t + 1]
        else:
            db["tokens"] = full["tokens"][:, t : t + 1]
        lg, cache = serve(params, cache, db)
        dec_logits.append(lg)
    dec = jnp.concatenate(dec_logits[:-1], axis=1)
    h, _, _ = T.forward_seq(cfg, params, full, None)
    ref = T.lm_logits(cfg, params, h)[:, S0 - 1 : S - 1]
    err = float(jnp.abs(dec - ref).max())
    assert err < 2e-3 * max(float(jnp.abs(ref).max()), 1.0), (arch, err)


def test_long_500k_applicability():
    """Assignment rule: long_500k runs only for sub-quadratic archs."""
    runs = {a for a in registry.ARCH_IDS if registry.build(a).cfg.supports("long_500k")}
    assert runs == {"mamba2-780m", "mixtral-8x22b", "zamba2-1.2b"}


def test_rolling_cache_swa():
    """SWA rolling cache: decoding past the window stays consistent with a
    full forward restricted by the window mask."""
    import repro.configs.mixtral_8x22b as mx

    cfg = dataclasses.replace(
        mx.SMOKE, window=8,
        moe=dataclasses.replace(mx.SMOKE.moe, capacity_factor=float(mx.SMOKE.moe.n_experts)),
    )
    B, S0, EXTRA = 2, 12, 6  # rolls past the 8-token window
    S = S0 + EXTRA
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    bundle = registry.Bundle(cfg)
    full = bundle.make_batch(ShapeCfg("t", "prefill", S, B), jax.random.PRNGKey(1),
                             act_dtype=jnp.float32)
    pre = {k: v[:, :S0] for k, v in full.items()}
    cache_shape = ShapeCfg("t", "decode", S, B)
    logits_p, cache = jax.jit(T.make_prefill_step(cfg, None, cache_shape))(params, pre)
    serve = jax.jit(T.make_serve_step(cfg, None))
    dec = [logits_p]
    for t in range(S0, S):
        lg, cache = serve(params, cache, {"tokens": full["tokens"][:, t : t + 1]})
        dec.append(lg)
    dec = jnp.concatenate(dec[:-1], axis=1)
    h, _, _ = T.forward_seq(cfg, params, full, None)
    ref = T.lm_logits(cfg, params, h)[:, S0 - 1 : S - 1]
    err = float(jnp.abs(dec - ref).max())
    assert err < 2e-3 * max(float(jnp.abs(ref).max()), 1.0), err

"""InferenceEngine facade: parity with the manual chain, EngineConfig JSON
round-trip, policy registries, and the request-level serving API."""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import compat
from repro.core import PartitionedEmbeddingBag
from repro.data.distributions import (
    HotSet,
    Uniform,
    Zipf,
    sample_workload,
    workload_probs,
)
from repro.data.workloads import small_workload
from repro.engine import (
    ACCESS_POLICIES,
    DRIFT_POLICIES,
    EngineConfig,
    InferenceEngine,
    PLACEMENT_POLICIES,
    PolicyRegistry,
    TUNING_POLICIES,
)


@pytest.fixture(scope="module")
def mesh():
    return compat.make_mesh((1, jax.device_count()), ("data", "model"))


@pytest.fixture(scope="module")
def wl():
    return small_workload(batch=16)


@pytest.fixture(scope="module")
def params(wl):
    bag = PartitionedEmbeddingBag(wl, n_cores=1)
    return bag.init(jax.random.PRNGKey(0))


def _indices(wl, dist, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    return jax.numpy.asarray(
        sample_workload(rng, wl, dist, batch or wl.batch)
    )


# -----------------------------------------------------------------------
# build parity vs the manual plan -> pack -> apply chain
# -----------------------------------------------------------------------


@pytest.mark.parametrize(
    "spec,dist",
    [(None, Uniform()), ("zipf:1.2", Zipf(1.2))],
    ids=["uniform", "zipf"],
)
def test_engine_matches_manual_chain(wl, params, mesh, spec, dist):
    """InferenceEngine.build reproduces the manual plan_asymmetric ->
    pack_plan -> PartitionedEmbeddingBag chain bit-for-bit."""
    kwargs = {}
    if spec is not None:
        kwargs["freqs"] = workload_probs(wl, dist)
    bag = PartitionedEmbeddingBag(
        wl, n_cores=1, planner="asymmetric", planner_kwargs=kwargs
    )
    packed = bag.pack(params)
    idx = _indices(wl, dist)
    ref = np.asarray(bag.apply(packed, idx, mesh=mesh))

    engine = InferenceEngine.build(
        params, wl, EngineConfig(distribution=spec, mesh_shape=(1, 1)), mesh=mesh
    )
    out = np.asarray(engine.lookup(idx))
    assert np.array_equal(out, ref)
    assert engine.plan.meta["planner"] == bag.plan.meta["planner"]


def test_engine_matches_manual_chain_with_access_reduction(wl, params, mesh):
    freqs = workload_probs(wl, Zipf(1.2))
    bag = PartitionedEmbeddingBag(
        wl, n_cores=1, planner="asymmetric",
        planner_kwargs=dict(freqs=freqs, dedup=True, cache=True),
    )
    packed = bag.pack(params)
    idx = _indices(wl, Zipf(1.2))
    ref = np.asarray(bag.apply(packed, idx, mesh=mesh))

    engine = InferenceEngine.build(
        params, wl,
        EngineConfig(distribution="zipf:1.2", access="full", mesh_shape=(1, 1)),
        mesh=mesh,
    )
    assert np.array_equal(np.asarray(engine.lookup(idx)), ref)
    assert engine.plan.meta["cache"] == bag.plan.meta["cache"]


def test_engine_abstract_and_fresh_tables(wl, mesh):
    eng = InferenceEngine.build(
        "abstract", wl, EngineConfig(mesh_shape=(1, 1)), mesh=mesh
    )
    assert eng.table_data is None
    eng2 = InferenceEngine.build(
        None, wl, EngineConfig(mesh_shape=(1, 1)), mesh=mesh,
        rng=jax.random.PRNGKey(7),
    )
    assert len(eng2.table_data) == len(wl.tables)
    with pytest.raises(ValueError, match="unknown tables spec"):
        InferenceEngine.build("bogus", wl, EngineConfig(mesh_shape=(1, 1)))


# -----------------------------------------------------------------------
# EngineConfig JSON round-trip
# -----------------------------------------------------------------------


def test_config_json_roundtrip_identical_plan(wl, params, mesh, tmp_path):
    """save -> load -> the rebuilt engine's plan/pack is identical,
    including plan.meta['cache'] and plan.meta['distribution']."""
    config = EngineConfig(
        distribution="zipf:1.2", access="full",
        access_options={"cache_target": 0.6}, mesh_shape=(1, 1),
        planner_options={"lpt": True},
    )
    path = tmp_path / "engine.json"
    config.save(path)
    loaded = EngineConfig.load(path)
    assert loaded == config

    a = InferenceEngine.build(params, wl, config, mesh=mesh)
    b = InferenceEngine.build(params, wl, loaded, mesh=mesh)
    assert a.plan.meta["cache"] == b.plan.meta["cache"]
    assert a.plan.meta["distribution"] == b.plan.meta["distribution"]
    assert a.plan.assignments == b.plan.assignments
    assert a.bag.layout_summary() == b.bag.layout_summary()
    idx = _indices(wl, Zipf(1.2))
    assert np.array_equal(
        np.asarray(a.lookup(idx)), np.asarray(b.lookup(idx))
    )


def test_config_rejects_unknown_fields_and_values():
    with pytest.raises(ValueError, match="unknown EngineConfig fields"):
        EngineConfig.from_dict({"planner": "asymmetric", "bogus": 1})
    with pytest.raises(ValueError, match="unknown layout"):
        EngineConfig(layout="diagonal").validate()
    with pytest.raises(ValueError, match="use_kernels"):
        EngineConfig(use_kernels="pallas").validate()
    # the access-reduction subsystem's structural requirements
    with pytest.raises(ValueError, match="planner='asymmetric'"):
        EngineConfig(access="full", planner="baseline").validate()
    with pytest.raises(ValueError, match="layout='ragged'"):
        EngineConfig(access="dedup", layout="dense").validate()
    with pytest.raises(ValueError, match="use_kernels='fused'"):
        EngineConfig(access="cache", use_kernels="xla").validate()


# -----------------------------------------------------------------------
# policy registries
# -----------------------------------------------------------------------


@pytest.mark.parametrize(
    "registry",
    [PLACEMENT_POLICIES, ACCESS_POLICIES, TUNING_POLICIES, DRIFT_POLICIES],
    ids=lambda r: r.kind,
)
def test_registry_unknown_name_lists_alternatives(registry):
    with pytest.raises(ValueError) as e:
        registry.create("no-such-policy")
    assert registry.kind in str(e.value)
    for name in registry.names():
        assert name in str(e.value)


def test_unknown_policy_name_fails_config_validate():
    with pytest.raises(ValueError, match="unknown placement policy"):
        EngineConfig(planner="no-such").validate()
    with pytest.raises(ValueError, match="unknown tuning policy"):
        EngineConfig(tuning="no-such").validate()


def test_custom_placement_policy_registration(wl, params, mesh):
    """A third-party policy registers by name and drives the build."""
    from repro.core.planner import plan_symmetric

    class EverythingSymmetric:
        def plan(self, workload, n_cores, model, **options):
            options.pop("freqs", None)
            return plan_symmetric(workload, n_cores, model)

    PLACEMENT_POLICIES.register("test-symmetric", EverythingSymmetric)
    try:
        eng = InferenceEngine.build(
            params, wl,
            EngineConfig(planner="test-symmetric", mesh_shape=(1, 1)), mesh=mesh,
        )
        assert eng.plan.meta["planner"] == "symmetric"
        assert len(eng.plan.assignments) == 0
        ref = PartitionedEmbeddingBag(wl, n_cores=1, planner="symmetric")
        idx = _indices(wl, Uniform())
        assert np.array_equal(
            np.asarray(eng.lookup(idx)),
            np.asarray(ref.apply(ref.pack(params), idx, mesh=mesh)),
        )
    finally:
        del PLACEMENT_POLICIES._factories["test-symmetric"]


def test_registry_decorator_and_bad_name():
    reg = PolicyRegistry("demo")

    @reg.register("thing")
    class Thing:
        pass

    assert isinstance(reg.create("thing"), Thing)
    assert reg.names() == ["thing"]
    with pytest.raises(ValueError, match="non-empty string"):
        reg.register("", lambda: None)


# -----------------------------------------------------------------------
# request-level serving
# -----------------------------------------------------------------------


def test_request_level_serving_handles(wl, params, mesh):
    engine = InferenceEngine.build(
        params, wl, EngineConfig(mesh_shape=(1, 1), max_wait_s=0.0), mesh=mesh
    )
    idx = np.asarray(_indices(wl, Zipf(1.2), batch=8))
    expected = np.asarray(engine.lookup(jax.numpy.asarray(idx)))

    srv = engine.serve(max_batch=8)
    handles = [srv.submit_request(idx[:, q]) for q in range(8)]
    assert not handles[0].done()
    with pytest.raises(RuntimeError, match="not served yet"):
        handles[0].result()
    srv.pump()
    assert all(h.done() for h in handles)
    for q, h in enumerate(handles):
        np.testing.assert_array_equal(np.asarray(h.result()), expected[:, q])
    # fire-and-forget submit still works alongside
    srv.submit(idx[:, 0])
    out = None
    while out is None:
        out = srv.pump()
    assert out.shape[0] == len(wl.tables)


def test_request_handle_split_error(wl, params, mesh):
    engine = InferenceEngine.build(
        params, wl, EngineConfig(mesh_shape=(1, 1), max_wait_s=0.0), mesh=mesh
    )

    def bad_split(out, n):
        raise KeyError("broken split")

    srv = engine.serve(max_batch=2, split_fn=bad_split)
    idx = np.asarray(_indices(wl, Uniform(), batch=2))
    h = srv.submit_request(idx[:, 0])
    srv.submit_request(idx[:, 1])
    srv.pump()
    assert h.done()
    with pytest.raises(KeyError, match="broken split"):
        h.result()
    # a split returning the wrong count must fail the handles too, not
    # leave the tail pending forever
    srv2 = engine.serve(max_batch=2, split_fn=lambda out, n: [out[:, 0]])
    h2 = srv2.submit_request(idx[:, 0])
    h3 = srv2.submit_request(idx[:, 1])
    srv2.pump()
    assert h2.done() and h3.done()
    with pytest.raises(ValueError, match="1 parts for a 2-query batch"):
        h3.result()


def test_engine_drift_replan_end_to_end(wl, params, mesh):
    """The drift policy wires sketch -> trigger -> engine.rebuild -> parity
    -> hot swap; the server's layout/cache records refresh on the swap."""
    engine = InferenceEngine.build(
        params, wl,
        EngineConfig(
            mesh_shape=(1, 1), use_kernels="xla", distribution="uniform",
            drift="replan",
            drift_options={"check_every": 2, "patience": 1, "cooldown": 2,
                           "threshold": 0.05},
        ),
        mesh=mesh,
    )
    srv = engine.serve(max_batch=16)
    rng = np.random.default_rng(3)
    hot = HotSet(n_hot=8, hot_mass=0.98)
    for _ in range(8):
        idx = sample_workload(rng, wl, hot, 16)
        for q in range(16):
            srv.submit(idx[:, q])
        srv.pump()
    s = srv.stats()
    assert s["replan"]["replans"] >= 1
    assert s["replan"]["parity_failures"] == 0
    # the swapped-in step carries the re-planned bag
    assert srv.step_fn.bag is not engine.bag
    assert "+freq" in srv.step_fn.bag.plan.meta["planner"]


# -----------------------------------------------------------------------
# introspection
# -----------------------------------------------------------------------


def test_stats_and_plan_report(wl, params, mesh):
    engine = InferenceEngine.build(
        params, wl,
        EngineConfig(distribution="zipf:1.2", access="full", mesh_shape=(1, 1)),
        mesh=mesh,
    )
    s = engine.stats()
    assert s["workload"] == wl.name
    assert s["n_chunks"] == len(engine.plan.assignments)
    assert s["predicted_p99_us"] > 0
    assert s["config"] == engine.config.to_dict()
    assert s["cache"]["dedup"] is True
    report = engine.plan_report()
    assert "access-reduction" in report and "planner=" in report
    # serving stats fold in once a server exists
    engine.serve(max_batch=4)
    assert "server" in engine.stats()


def test_engine_config_dataclass_fields_json_representable():
    """Every EngineConfig field must survive JSON (the one-artifact
    reproducibility contract)."""
    cfg = EngineConfig()
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        assert v is None or isinstance(v, (str, int, float, bool, dict)), (
            f.name
        )
    assert EngineConfig.from_json(cfg.to_json()) == cfg

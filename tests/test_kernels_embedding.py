"""Per-kernel correctness: every Pallas strategy vs the pure-jnp oracle,
swept over shapes and dtypes (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.strategies import ALL_STRATEGIES, Strategy
from repro.kernels import ops, ref

SHAPES = [
    # (rows, dim, batch, seq)
    (16, 16, 4, 1),
    (100, 16, 32, 4),
    (1000, 32, 64, 2),
    (64, 128, 16, 3),
    (513, 64, 33, 5),  # non-aligned rows/batch
    (2048, 16, 128, 1),
]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


def _tol(dtype):
    return {"float32": 1e-5, "bfloat16": 2e-2, "float16": 2e-3}[jnp.dtype(dtype).name]


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
@pytest.mark.parametrize("shape", SHAPES)
def test_strategy_matches_ref(strategy, shape):
    m, e, b, s = shape
    table = jax.random.normal(jax.random.PRNGKey(0), (m, e), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, m)
    got = ops.embedding_bag(table, idx, strategy, interpret=True)
    want = ref.embedding_bag_ref(table, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategy_dtypes(strategy, dtype):
    m, e, b, s = 200, 16, 32, 4
    table = (jax.random.normal(jax.random.PRNGKey(0), (m, e), jnp.float32) * 0.5).astype(dtype)
    idx = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, m)
    got = ops.embedding_bag(table, idx, strategy, interpret=True)
    want = ref.embedding_bag_ref(table, idx)
    assert got.dtype == table.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype),
    )


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_mean_pooling(strategy):
    m, e, b, s = 64, 16, 8, 4
    table = jax.random.normal(jax.random.PRNGKey(0), (m, e), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, m)
    got = ops.embedding_bag(table, idx, strategy, pooling="mean", interpret=True)
    want = ref.embedding_bag_ref(table, idx, pooling="mean")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_gather_is_seq1_bag():
    m, e, t = 128, 32, 17
    table = jax.random.normal(jax.random.PRNGKey(0), (m, e), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (t,), 0, m)
    got = ops.embedding_gather(table, idx, Strategy.L1_UB, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.gather_ref(table, idx)),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(4, 300),
    e=st.sampled_from([8, 16, 32]),
    b=st.integers(1, 48),
    s=st.integers(1, 6),
    strategy=st.sampled_from(list(ALL_STRATEGIES)),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_any_shape(m, e, b, s, strategy, seed):
    """Property: for any table/index shapes, every strategy == oracle."""
    table = jax.random.normal(jax.random.PRNGKey(seed), (m, e), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s), 0, m)
    got = ops.embedding_bag(table, idx, strategy, interpret=True)
    want = ref.embedding_bag_ref(table, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_chunk_bag_partition_identity():
    """Summing chunked (offset/clip/mask) partial pools over a row partition
    reconstructs the full bag exactly — the paper's §III-B correctness core."""
    m, e, b, s = 97, 16, 24, 3
    table = jax.random.normal(jax.random.PRNGKey(0), (m, e), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, m)
    want = ref.embedding_bag_ref(table, idx)
    cuts = [0, 13, 50, 51, 97]
    acc = jnp.zeros((b, e))
    for lo, hi in zip(cuts[:-1], cuts[1:]):
        acc = acc + ref.chunk_bag_ref(table[lo:hi], idx, lo)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_negative_index_padding_masked():
    m, e, b, s = 50, 16, 8, 4
    table = jax.random.normal(jax.random.PRNGKey(0), (m, e), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, m)
    idx = idx.at[:, -1].set(-1)  # padded lookups
    got = ref.chunk_bag_ref(table, idx, 0)
    want = ref.embedding_bag_ref(table, idx.at[:, -1].set(0)) - jnp.take(
        table, idx.at[:, -1].set(0)[:, -1], axis=0
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_kernel_custom_vjp(strategy):
    """Pallas strategy kernels are differentiable: grads == oracle grads."""
    m, e, b, s = 64, 16, 8, 3
    table = jax.random.normal(jax.random.PRNGKey(0), (m, e), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, m)
    w = jax.random.normal(jax.random.PRNGKey(2), (b, e))

    gk = jax.grad(lambda t: jnp.sum(
        ops.embedding_bag(t, idx, strategy, interpret=True) * w))(table)
    gr = jax.grad(lambda t: jnp.sum(ref.embedding_bag_ref(t, idx) * w))(table)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-5, atol=1e-5)

"""Scenario-matrix conformance battery (DESIGN.md §10).

One parametrized battery over every entry in
``repro.models.registry.SCENARIOS``: a registered model that stops
satisfying the wrapper protocol, loses bit-parity against its reference
forward, or breaks under a drift hot-swap fails here — in CI, not in
review.  The registry smoke tests at the bottom validate the config side:
every ``default_config`` must round-trip through
``EngineConfig.from_dict(...).validate()``, and every ``ARCH_MODULES``
entry must still export CONFIG/SMOKE.
"""
import dataclasses
import importlib

import numpy as np
import pytest

from repro.data.distributions import Zipf, get_distribution, workload_probs
from repro.engine import EngineConfig, InferenceEngine
from repro.models.registry import (
    ARCH_MODULES,
    SCENARIOS,
    get_scenario,
    list_scenarios,
)
from repro.models.scenarios import ScenarioModel

BATCH = 16


@pytest.fixture(scope="module", params=sorted(SCENARIOS))
def cell(request):
    """One (scenario, engine) pair per registered model, built through the
    entry's own default_config — shared across the battery so each tower
    compiles once."""
    name = request.param
    scenario = get_scenario(name, batch=BATCH)
    cfg = EngineConfig.from_dict(
        {**SCENARIOS[name].default_config, "mesh_shape": (1, 1)}
    )
    engine = InferenceEngine.from_scenario(scenario, cfg)
    return scenario, engine


def test_protocol_conformance(cell):
    scenario, _ = cell
    assert isinstance(scenario, ScenarioModel)
    assert scenario.name in SCENARIOS
    assert scenario.workload.batch == BATCH


def test_table_extraction_matches_workload(cell):
    scenario, _ = cell
    tables = scenario.table_data()
    specs = scenario.workload.tables
    assert len(tables) == len(specs)
    for arr, spec in zip(tables, specs):
        assert arr.shape == (spec.rows, spec.dim)


def test_config_stamps_model_name(cell):
    scenario, engine = cell
    assert engine.config.model == scenario.name
    assert engine.stats()["model"] == scenario.name
    assert f"model {scenario.name}" in engine.plan_report()


def test_step_parity_bitwise(cell):
    """Fused engine step == dense reference forward, bit for bit: all
    scenario tables are seq=1, so the one-hot fused path is exact."""
    scenario, engine = cell
    rng = np.random.default_rng(0)
    batch = scenario.sample_batch(rng, Zipf(1.2))
    step = scenario.make_step(engine)
    got = np.asarray(step(scenario.payloads(batch)))
    want = scenario.reference_forward(batch)
    assert got.shape == (BATCH,)
    np.testing.assert_array_equal(got, want)


def test_rebuild_after_drift_hot_swap(cell):
    """The drift policy's shadow re-pack: rebuild under skewed histograms,
    re-invoke make_step on the rebuilt engine, keep bit-parity."""
    scenario, engine = cell
    freqs = workload_probs(scenario.workload, Zipf(1.2))
    rebuilt = engine.rebuild(freqs)
    assert rebuilt.scenario is scenario
    rng = np.random.default_rng(1)
    batch = scenario.sample_batch(rng, Zipf(1.2))
    got = np.asarray(scenario.make_step(rebuilt)(scenario.payloads(batch)))
    np.testing.assert_array_equal(got, scenario.reference_forward(batch))


def test_served_roundtrip(cell):
    """Request-level parity through engine.serve + submit_request using the
    scenario's default make_step/split wiring (no explicit step passed)."""
    scenario, engine = cell
    srv = engine.serve(max_batch=8, max_wait_s=0.0)
    rng = np.random.default_rng(2)
    batch = scenario.sample_batch(rng, Zipf(1.2), batch=8)
    handles = [srv.submit_request(p) for p in scenario.payloads(batch)]
    srv.pump(force=True)
    got = np.asarray([h.result() for h in handles])
    np.testing.assert_array_equal(got, scenario.reference_forward(batch))


def test_distribution_sampling_in_range(cell):
    scenario, _ = cell
    rng = np.random.default_rng(3)
    for spec in ("uniform", "zipf:1.2", "hotset:0.02:0.9"):
        idx = np.asarray(
            scenario.sample_batch(rng, get_distribution(spec))["indices"]
        )
        assert idx.shape[:2] == (len(scenario.workload.tables), BATCH)
        for i, t in enumerate(scenario.workload.tables):
            valid = idx[i][idx[i] >= 0]
            assert valid.size and valid.max() < t.rows


def test_forced_sparse_kernel_cell():
    """A forced kernel_path='sparse' matrix cell (DESIGN.md §11): the dlrm
    scenario under its dedup-armed default config serves bit-identically
    whether the dedup'd gather runs one-hot or true-sparse, and both match
    the dense reference forward."""
    scenario = get_scenario("dlrm", batch=BATCH)
    base = {**SCENARIOS["dlrm"].default_config, "mesh_shape": (1, 1)}
    outs = {}
    engines = {}
    rng_batch = scenario.sample_batch(np.random.default_rng(4), Zipf(1.2))
    for kp in ("onehot", "sparse"):
        cfg = EngineConfig.from_dict({**base, "kernel_path": kp})
        engines[kp] = InferenceEngine.from_scenario(scenario, cfg)
        step = scenario.make_step(engines[kp])
        outs[kp] = np.asarray(step(scenario.payloads(rng_batch)))
    assert engines["sparse"].packed.kernel_path == "sparse"
    assert engines["onehot"].packed.kernel_path == "onehot"
    np.testing.assert_array_equal(outs["sparse"], outs["onehot"])
    np.testing.assert_array_equal(
        outs["sparse"], scenario.reference_forward(rng_batch)
    )


# -----------------------------------------------------------------------
# registry smoke: configs validate, arch modules import
# -----------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_default_config_validates(name):
    """Every registered default_config is a valid EngineConfig recipe —
    unknown or renamed fields fail here, not at build time."""
    entry = SCENARIOS[name]
    cfg = EngineConfig.from_dict({**entry.default_config, "model": name})
    cfg.validate()
    assert cfg.model == name


def test_unknown_config_field_rejected():
    entry = next(iter(SCENARIOS.values()))
    with pytest.raises((TypeError, ValueError)):
        EngineConfig.from_dict(
            {**entry.default_config, "not_a_field": 1}
        )


def test_unknown_model_name_rejected():
    with pytest.raises(ValueError, match="unknown"):
        EngineConfig(model="nope").validate()
    with pytest.raises(ValueError, match="nope"):
        get_scenario("nope")


def test_list_scenarios_sorted_and_complete():
    assert list_scenarios() == sorted(SCENARIOS)
    assert set(list_scenarios()) == {"dlrm", "moe", "mamba2", "transformer"}


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_arch_registry_configs_importable(arch):
    """Every --arch entry's module exports CONFIG and SMOKE ArchConfigs
    with coherent shapes (a renamed module or field fails here)."""
    mod = importlib.import_module(ARCH_MODULES[arch])
    for cfg in (mod.CONFIG, mod.SMOKE):
        assert dataclasses.is_dataclass(cfg)
        assert cfg.d_model > 0 and cfg.n_layers > 0 and cfg.vocab > 0


def test_build_scenario_by_name():
    eng = InferenceEngine.build_scenario(
        "transformer", EngineConfig(mesh_shape=(1, 1)), batch=8
    )
    assert eng.config.model == "transformer"
    assert eng.scenario is not None
    assert eng.scenario.workload.batch == 8

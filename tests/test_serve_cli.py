"""launch/serve.py CLI: legacy flag spellings map onto EngineConfig with
DeprecationWarnings, and the canonical --config/--set surface is equivalent."""
import warnings

import pytest

from repro.engine import EngineConfig
from repro.launch.serve import build_parser, config_from_args


def _resolve(argv):
    args = build_parser().parse_args(argv)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cfg = config_from_args(args)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    return cfg, dep


# every legacy spelling next to its canonical --set equivalent; the two must
# resolve to the SAME EngineConfig (legacy additionally warns)
LEGACY_CASES = [
    (["--planner", "symmetric"], ["--set", "planner=symmetric"], 1),
    (["--planner", "asymmetric"], [], 1),  # the default, spelled explicitly
    (["--layout", "dense"], ["--set", "layout=dense"], 1),
    (["--kernels", "xla"], ["--set", "use_kernels=xla"], 1),
    (["--reduce", "psum"], ["--set", "reduce_mode=psum"], 1),
    (["--reduce", "ring"], ["--set", "reduce_mode=ring"], 1),
    (["--autotune"], ["--set", "tuning=sweep"], 1),
    (["--dedup"], ["--set", "access=dedup"], 1),
    (["--cache"], ["--set", "access=cache"], 1),
    (["--dedup", "--cache"], ["--set", "access=full"], 2),
    (["--replan"], ["--set", "drift=replan"], 1),
    (
        ["--replan", "--replan-threshold", "0.3"],
        ["--set", "drift=replan",
         "--set", 'drift_options={"threshold": 0.3}'],
        2,
    ),
    # threshold alone is recorded but does NOT arm replanning (the old
    # CLI ignored it without --replan)
    (
        ["--replan-threshold", "0.3"],
        ["--set", 'drift_options={"threshold": 0.3}'],
        1,
    ),
]


@pytest.mark.parametrize(
    "legacy,canonical,n_warnings",
    LEGACY_CASES,
    ids=[" ".join(c[0]) for c in LEGACY_CASES],
)
def test_legacy_flag_equivalent_config(legacy, canonical, n_warnings):
    legacy_cfg, dep = _resolve(legacy)
    assert len(dep) == n_warnings
    for w in dep:
        assert "deprecated" in str(w.message)
        assert "EngineConfig" in str(w.message)
    canonical_cfg, dep_canon = _resolve(canonical)
    assert not dep_canon, "the canonical spelling must not warn"
    assert legacy_cfg == canonical_cfg


def test_defaults_do_not_warn():
    cfg, dep = _resolve([])
    assert not dep
    assert cfg.planner == "asymmetric"
    # the serve CLI's historical choices are baked into the resolved config
    assert cfg.planner_options == {"shard_rocks": True}
    assert cfg.distribution == "real"  # traffic default doubles as pricing
    assert cfg.drift == "none"


def test_replan_gets_cli_trigger_cadence():
    cfg, _ = _resolve(["--replan"])
    assert cfg.drift == "replan"
    assert cfg.drift_options == {
        "check_every": 4, "patience": 2, "cooldown": 8,
    }


def test_distribution_all_prices_uniform_leg():
    cfg, _ = _resolve(["--distribution", "all"])
    assert cfg.distribution == "uniform"


def test_batch_flags_flow_into_serving_config():
    cfg, _ = _resolve(["--batch", "64"])
    assert cfg.max_batch == 64 and cfg.max_wait_s == 0.0


def test_replan_threshold_alone_stays_static():
    cfg, dep = _resolve(["--replan-threshold", "0.3"])
    assert len(dep) == 1
    assert cfg.drift == "none"
    assert cfg.drift_options == {"threshold": 0.3}


def test_set_and_config_serving_knobs_not_clobbered(tmp_path):
    # --set wins over --batch; a --config file's serving knobs survive
    cfg, _ = _resolve(["--batch", "64", "--set", "max_batch=512"])
    assert cfg.max_batch == 512
    base = EngineConfig(max_batch=128, max_wait_s=0.002)
    path = tmp_path / "engine.json"
    base.save(path)
    cfg2, _ = _resolve(["--config", str(path)])
    assert cfg2.max_batch == 128 and cfg2.max_wait_s == 0.002
    cfg3, _ = _resolve(["--config", str(path), "--batch", "64"])
    assert cfg3.max_batch == 64  # explicit --batch overrides the file


def test_config_file_roundtrip(tmp_path):
    base = EngineConfig(distribution="zipf:1.4", access="full",
                        tuning="sweep")
    path = tmp_path / "engine.json"
    base.save(path)
    cfg, dep = _resolve(["--config", str(path)])
    assert not dep
    assert cfg.access == "full" and cfg.tuning == "sweep"
    assert cfg.distribution == "zipf:1.4"  # config pins pricing over traffic
    # legacy flags still override a loaded config (with the warning)
    cfg2, dep2 = _resolve(["--config", str(path), "--reduce", "psum"])
    assert len(dep2) == 1 and cfg2.reduce_mode == "psum"


def test_set_rejects_unknown_field():
    args = build_parser().parse_args(["--set", "bogus=1"])
    with pytest.raises(SystemExit):
        config_from_args(args)


# ------------------------------------------------------------ preset packs


def test_list_presets_names_the_curated_packs():
    from repro.configs.presets import list_presets

    names = list_presets()
    assert {"taobao-zipf12", "tenrec-hotset", "huawei-dayparted"} <= set(names)


@pytest.mark.parametrize("name", [
    "taobao-zipf12", "tenrec-hotset", "huawei-dayparted",
])
def test_load_preset_validates_and_roundtrips(name):
    from repro.configs.presets import load_preset

    data = load_preset(name)
    assert data["name"] == name
    assert data["description"]
    # the embedded config is a valid EngineConfig (load_preset validates,
    # but the round-trip must also be loss-free)
    cfg = EngineConfig.from_dict(data["config"])
    assert cfg.to_dict() | data["config"] == cfg.to_dict()


def test_load_preset_unknown_name_lists_alternatives():
    from repro.configs.presets import load_preset

    with pytest.raises(ValueError, match="taobao-zipf12"):
        load_preset("nope")


def test_preset_fills_config_workload_and_distribution():
    cfg, dep = _resolve(["--preset", "tenrec-hotset"])
    assert not dep
    assert cfg.validation == "null-row" and cfg.integrity == "checksum"
    assert cfg.access == "full" and cfg.admission == "shed-oldest"
    # the preset also resolved the driver flags on the namespace
    args = build_parser().parse_args(["--preset", "tenrec-hotset"])
    config_from_args(args)
    assert args.workload == "tenrec-qb"
    assert args.distribution == "tenrec-qb"


def test_explicit_flags_override_preset():
    args = build_parser().parse_args(
        ["--preset", "taobao-zipf12", "--workload", "smoke",
         "--distribution", "uniform", "--set", "max_batch=64"]
    )
    cfg = config_from_args(args)
    assert args.workload == "smoke" and args.distribution == "uniform"
    assert cfg.max_batch == 64
    assert cfg.drift == "replan"  # the rest of the pack survives


def test_preset_and_config_are_mutually_exclusive(tmp_path):
    path = tmp_path / "engine.json"
    EngineConfig().save(path)
    args = build_parser().parse_args(
        ["--preset", "taobao-zipf12", "--config", str(path)]
    )
    with pytest.raises(SystemExit, match="mutually exclusive"):
        config_from_args(args)


def test_structural_validation_still_enforced():
    # the old `p.error("--dedup/--cache require ...")` checks now live in
    # EngineConfig.validate
    args = build_parser().parse_args(["--dedup", "--planner", "baseline"])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(ValueError, match="planner='asymmetric'"):
            config_from_args(args)
    args = build_parser().parse_args(["--cache", "--kernels", "xla"])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(ValueError, match="use_kernels='fused'"):
            config_from_args(args)

"""Query-distribution engine tests (DESIGN.md §5).

Covers: generator/histogram exactness, RowProbs mass queries, drift metrics
(stationary vs drifted separation), the frequency sketch, schedules/presets,
frequency-aware cost-model pricing, and the planner's hot-window L1/UB
promotion that the uniform assumption would never make.
"""
import collections
import dataclasses

import numpy as np
import pytest

from repro.core import analytic_model, modeled_plan_traffic
from repro.core.cost_model import TPU_V5E
from repro.core.planner import plan_asymmetric, predicted_p99
from repro.core.strategies import Strategy
from repro.core.tables import TableSpec, make_workload
from repro.data import synthetic
from repro.data.distributions import (
    PRESETS,
    DriftSchedule,
    Fixed,
    FrequencySketch,
    HotSet,
    RowProbs,
    Uniform,
    Zipf,
    drift_distance,
    empirical_probs,
    get_distribution,
    parse_drift,
    sample_workload,
    workload_probs,
)
from repro.data.workloads import WORKLOADS, small_workload

T = TableSpec("t", rows=50_000, dim=16, seq=2)
ALL_DISTS = [
    Uniform(),
    Fixed(7),
    Zipf(1.2),
    Zipf(1.6, hot_prefix=False),
    HotSet(0.01, 0.9),
    HotSet(0.01, 0.9).flip(),
]


# ------------------------------------------------------------- histograms


@pytest.mark.parametrize("dist", ALL_DISTS, ids=lambda d: repr(d)[:30])
def test_probs_normalized_and_in_range(dist):
    rp = dist.probs(T)
    assert abs(float(rp.probs.sum()) + rp.tail - 1.0) < 1e-9
    assert rp.ids.min(initial=0) >= 0
    assert rp.ids.max(initial=0) < T.rows
    # probs are rank-sorted descending
    assert (np.diff(rp.probs) <= 1e-15).all()


@pytest.mark.parametrize("dist", ALL_DISTS, ids=lambda d: repr(d)[:30])
def test_sampler_within_table_and_matches_histogram(dist):
    rng = np.random.default_rng(0)
    idx = dist.sample(rng, T, 8192)
    assert idx.shape == (8192, T.seq)
    assert idx.min() >= 0 and idx.max() < T.rows
    # the sampler draws from the same histogram probs() reports: large-sample
    # empirical mass over the analytic hot ids converges (rank-ordered
    # top_mass of an empirical histogram is upward-biased on sparse uniform
    # samples, so compare mass at the *analytic* hot ids instead)
    emp = empirical_probs(idx, T.rows)
    rp = dist.probs(T)
    for k in (1, 64, 1024):
        ids = rp.ids[: min(k, len(rp.ids))]
        if len(ids):
            assert emp.mass_of_ids(ids) == pytest.approx(
                rp.mass_of_ids(ids), abs=0.05
            )
    assert drift_distance(emp, rp) < 0.15


def test_empirical_histogram_exact_counts():
    """empirical_probs counts the stream exactly (vs a naive Counter)."""
    rng = np.random.default_rng(1)
    idx = rng.integers(-1, 100, (64, 3))  # includes -1 padding
    rp = empirical_probs(idx, rows=100)
    counter = collections.Counter(int(v) for v in idx.ravel() if v >= 0)
    total = sum(counter.values())
    assert rp.tail == pytest.approx(0.0, abs=1e-12)
    for i, p in zip(rp.ids, rp.probs):
        assert p == pytest.approx(counter[int(i)] / total)
    assert len(rp.ids) == len(counter)


def test_rowprobs_mass_queries():
    u = RowProbs.uniform(1000)
    assert u.prefix_mass(100) == pytest.approx(0.1)
    assert u.range_mass(500, 600) == pytest.approx(0.1)
    assert u.effective_rows(0.99) == 990
    h = HotSet(n_hot=10, hot_frac=0.0, hot_mass=0.9, offset=100).probs(
        TableSpec("x", rows=1000, dim=16)
    )
    assert h.range_mass(100, 110) == pytest.approx(0.9)
    assert h.range_mass(0, 100) == pytest.approx(0.1 * 100 / 990)
    assert h.effective_rows(0.9) == 10
    # zipf hot-prefix concentrates mass at low ids; scattered does not
    zp = Zipf(1.4).probs(T)
    zs = Zipf(1.4, hot_prefix=False).probs(T)
    assert zp.prefix_mass(1024) > 0.8
    assert zs.prefix_mass(1024) < 0.3
    assert zp.effective_rows(0.5) == zs.effective_rows(0.5)  # rank-identical


def test_l1_distance_properties():
    a = Zipf(1.2).probs(T)
    assert a.l1_distance(a) == pytest.approx(0.0, abs=1e-9)
    h1 = HotSet(0.01, 0.9).probs(T)
    h2 = HotSet(0.01, 0.9).flip().probs(T)
    d = h1.l1_distance(h2)
    assert 1.5 < d <= 2.0  # disjoint hot blocks: nearly total variation 2
    with pytest.raises(ValueError):
        a.l1_distance(RowProbs.uniform(10))


# ----------------------------------------------------------- drift metric


def test_drift_distance_stationary_vs_drifted():
    """The serving trigger's core property: sparse-sample noise on
    stationary traffic stays well below genuine distribution drift."""
    rng = np.random.default_rng(2)
    stationary, drifted = [], []
    for dist in (Uniform(), Zipf(1.2), HotSet(0.01, 0.9)):
        base = dist.probs(T)
        emp = empirical_probs(dist.sample(rng, T, 1024), T.rows)
        stationary.append(drift_distance(emp, base))
    pairs = [
        (Zipf(1.2), Uniform()),
        (Uniform(), Zipf(1.2)),
        (HotSet(0.01, 0.9).flip(), HotSet(0.01, 0.9)),
    ]
    for gen, assumed in pairs:
        emp = empirical_probs(gen.sample(rng, T, 1024), T.rows)
        drifted.append(drift_distance(emp, assumed.probs(T)))
    assert max(stationary) < 0.2, stationary
    assert min(drifted) > 0.3, drifted


def test_sketch_exact_under_capacity_and_bounded_over():
    rng = np.random.default_rng(3)
    idx = rng.integers(0, 200, 5000)
    sk = FrequencySketch(rows=200, capacity=256)
    sk.update(idx)
    exact = empirical_probs(idx, 200)
    got = sk.to_probs()
    assert got.l1_distance(exact) == pytest.approx(0.0, abs=1e-9)
    assert sk.total == 5000
    # over capacity: bounded memory, hot ids still dominate
    big = FrequencySketch(rows=1_000_000, capacity=64)
    stream = Zipf(2.0).sample(rng, TableSpec("b", rows=1_000_000, dim=16), 4096)
    big.update(stream)
    assert len(big.counts) <= 64
    assert big.to_probs().top_mass(8) > 0.5


def test_schedules_and_presets():
    sch = DriftSchedule([(4, Uniform()), (4, Zipf(1.2))])
    assert isinstance(sch.at(0), Uniform)
    assert isinstance(sch.at(4), Zipf)
    assert isinstance(sch.at(9), Uniform)  # cycles: 9 % 8 = 1 -> phase 0
    assert isinstance(sch.at(13), Zipf)  # 13 % 8 = 5 -> phase 1
    assert sch.phase_index(9) == 0
    flip = parse_drift("flip", phase_batches=8)
    assert flip.period == 24 and not flip.cycle
    assert [type(flip.at(i)).__name__ for i in (0, 8, 16)] == [
        "Uniform", "Zipf", "HotSet"]
    assert set(PRESETS) == set(WORKLOADS)
    assert isinstance(get_distribution("zipf:1.5"), Zipf)
    assert get_distribution("zipf:1.5").alpha == 1.5
    hs = get_distribution("hotset:0.02:0.8:-1")
    assert (hs.hot_frac, hs.hot_mass, hs.offset) == (0.02, 0.8, -1)
    with pytest.raises(ValueError):
        get_distribution("nope")


def test_sample_workload_shapes_and_padding():
    wl = small_workload(batch=16)
    idx = sample_workload(np.random.default_rng(0), wl, Zipf(1.2))
    s_max = max(t.seq for t in wl.tables)
    assert idx.shape == (len(wl.tables), 16, s_max)
    for i, t in enumerate(wl.tables):
        assert (idx[i, :, t.seq:] == -1).all()
        assert (idx[i, :, : t.seq] >= 0).all()


# ------------------------------------------- synthetic.py deprecation shim


def test_synthetic_string_path_deprecated_but_working():
    rng = np.random.default_rng(0)
    with pytest.warns(DeprecationWarning):
        idx = synthetic.sample_indices(rng, T, 32, "real")
    assert idx.shape == (32, T.seq)
    with pytest.warns(DeprecationWarning):
        fixed = synthetic.sample_indices(rng, T, 32, "fixed")
    assert len(np.unique(fixed)) == 1


def test_synthetic_object_path_no_warning(recwarn):
    rng = np.random.default_rng(0)
    wl = small_workload(batch=8)
    idx = synthetic.query_batch(rng, wl, Zipf(1.2))
    assert idx.shape[1] == 8
    batch = synthetic.ctr_batch(rng, wl, distribution=Uniform())
    assert batch["indices"].shape[1] == wl.batch
    assert not any(
        issubclass(w.category, DeprecationWarning) for w in recwarn.list
    )


# ------------------------------------------- frequency-aware cost/planner


def _drift_model():
    return analytic_model(
        dataclasses.replace(TPU_V5E, l1_bytes=64 << 10, dma_latency=1e-8)
    )


def test_predict_freq_none_is_degenerate():
    """freq=None reproduces the uniform-assumption model bit-for-bit."""
    m = analytic_model()
    t = TableSpec("t", rows=5000, dim=16, seq=3)
    for s in Strategy:
        assert m.predict(t, 512, 4, s) == m.predict(t, 512, 4, s, None)


def test_predict_mass_scaling_and_conflict():
    m = _drift_model()
    t = TableSpec("t", rows=10_000, dim=16, seq=1)
    hot = HotSet(n_hot=64, hot_frac=0.0, hot_mass=0.95).probs(t)
    uni = Uniform().probs(t)
    # a chunk carrying ~no mass pays ~no work (only the b0 launch constant)
    b0 = m.betas[Strategy.L1][0]
    lo_mass = m.predict(t, 1024, 1, Strategy.L1, hot, (5000, 10_000))
    full = m.predict(t, 1024, 1, Strategy.L1, hot, (0, 10_000))
    assert lo_mass - b0 < 0.1 * (full - b0)
    # GM pays the conflict surcharge under concentration, L1/UB do not
    gm_uni = m.predict(t, 1024, 1, Strategy.GM, uni)
    gm_hot = m.predict(t, 1024, 1, Strategy.GM, hot)
    assert gm_hot > 3 * gm_uni
    for s in (Strategy.L1, Strategy.L1_UB, Strategy.GM_UB):
        assert m.predict(t, 1024, 1, s, hot) <= m.predict(t, 1024, 1, s, uni) * 1.01


def test_planner_promotes_hot_window_to_l1():
    """The headline frequency-aware decision: a table too big for L1 under
    the uniform assumption gets its hot window pinned once the histogram
    shows the mass concentrates there — wherever the window sits."""
    model = _drift_model()
    wl = make_workload("hot", [200_000, 300, 500], batch=256)
    l1_rows = (model.hardware.l1_bytes // wl.tables[0].row_bytes)

    plan_uni = plan_asymmetric(wl, 4, model, freqs=workload_probs(wl, Uniform()))
    assert not any(
        a.table_idx == 0 and a.strategy.is_l1 for a in plan_uni.assignments
    ), "uniform histogram must not promote the oversized table"

    for dist in (Zipf(1.2), HotSet(0.005, 0.95), HotSet(0.005, 0.95).flip()):
        freqs = workload_probs(wl, dist)
        plan = plan_asymmetric(wl, 4, model, freqs=freqs)
        plan.validate(wl.tables)
        hot_chunks = [
            a for a in plan.assignments
            if a.table_idx == 0 and a.strategy.is_l1
        ]
        assert hot_chunks, f"no L1 promotion under {dist!r}"
        (hc,) = hot_chunks
        assert hc.rows <= l1_rows
        # the pinned window actually covers the hot mass
        assert freqs[0].range_mass(hc.row_offset, hc.row_offset + hc.rows) > 0.5
        # and the promotion pays: less modeled traffic + lower predicted P99
        assert (
            modeled_plan_traffic(plan, wl.tables, wl.batch, freqs)[
                "hbm_lookup_bytes"]
            < modeled_plan_traffic(plan_uni, wl.tables, wl.batch, freqs)[
                "hbm_lookup_bytes"]
        )
        assert predicted_p99(model, wl.tables, wl.batch, plan, freqs) <= (
            predicted_p99(model, wl.tables, wl.batch, plan_uni, freqs)
        )
        assert plan.meta["planner"].endswith("+freq")
        assert plan.meta["distribution"]["per_table"][0]["rows"] == 200_000


def test_stale_plan_degrades_replanned_stays_bounded():
    """The driftbench acceptance property at unit scale."""
    model = _drift_model()
    wl = make_workload("hot", [200_000, 300, 500], batch=256)
    hs = workload_probs(wl, HotSet(0.005, 0.95))
    flipped = workload_probs(wl, HotSet(0.005, 0.95).flip())
    plan_hs = plan_asymmetric(wl, 4, model, freqs=hs)
    plan_flip = plan_asymmetric(wl, 4, model, freqs=flipped)
    matched = predicted_p99(model, wl.tables, wl.batch, plan_hs, hs)
    stale = predicted_p99(model, wl.tables, wl.batch, plan_hs, flipped)
    replanned = predicted_p99(model, wl.tables, wl.batch, plan_flip, flipped)
    assert stale > 1.2 * matched
    assert replanned < 1.05 * matched
